"""Benchmark harness: one function per paper table/figure + serve-path perf.

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.Report) and
writes the machine-readable ``BENCH_serve.json`` (probe/insert/serve_step
throughput and ref-vs-pallas speedups) so the perf trajectory is tracked
PR over PR.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,table2]
    PYTHONPATH=src python -m benchmarks.run --quick      # CI smoke subset
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

from benchmarks import common
from benchmarks.common import Report

BENCHES = [
    ("fig2_access_pattern", "benchmarks.bench_access_pattern"),
    ("fig6_hit_rate", "benchmarks.bench_hit_rate"),
    ("table2_direct_cache", "benchmarks.bench_direct_cache"),
    ("table3_failover", "benchmarks.bench_failover"),
    ("table4_ttl_ne", "benchmarks.bench_ttl_ne"),
    ("fig7_8_9_serving_cost", "benchmarks.bench_serving_cost"),
    ("fig10_drain", "benchmarks.bench_drain"),
    ("capacity_beyond_paper", "benchmarks.bench_capacity"),
    ("roofline", "benchmarks.bench_roofline"),
    ("kernel_probe", "benchmarks.bench_kernel_probe"),
    ("serve_path", "benchmarks.bench_serve"),
    ("multi_model", "benchmarks.bench_multi_model"),
    ("eviction", "benchmarks.bench_eviction"),
    ("overload", "benchmarks.bench_overload"),
    ("stream", "benchmarks.bench_stream"),
    ("restart", "benchmarks.bench_restart"),
    ("shard", "benchmarks.bench_shard"),
    ("regions", "benchmarks.bench_regions"),
    ("chaos", "benchmarks.bench_chaos"),
]

# the fast, serve-path-focused subset run by CI (--quick with no --only)
QUICK_BENCHES = ("kernel_probe", "serve_path", "multi_model", "eviction",
                 "overload", "stream", "restart", "shard", "regions",
                 "chaos")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes + serve-path benches only (CI smoke)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="path for the machine-readable serve metrics "
                         "('' disables)")
    args = ap.parse_args()
    common.QUICK = args.quick
    common.WRITE_JSON = bool(args.json)
    if args.only:
        only = args.only.split(",")
    elif args.quick:
        only = list(QUICK_BENCHES)
    else:
        only = None

    import jax

    report = Report()
    metrics = {
        "schema": "ercache-bench-serve/1",
        "quick": args.quick,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "benches": {},
    }
    t_start = time.perf_counter()
    for name, module in BENCHES:
        if only and not any(f in name for f in only):
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        mod = __import__(module, fromlist=["run"])
        try:
            out = mod.run(report)
            if isinstance(out, dict):
                metrics["benches"][name] = out
        except Exception as e:  # keep the harness going; record the failure
            report.add(f"{name}_FAILED", 0.0, f"{type(e).__name__}: {e}")
            metrics["benches"][name] = {"error": f"{type(e).__name__}: {e}"}
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr, flush=True)
    metrics["wall_s"] = round(time.perf_counter() - t_start, 1)
    report.print_csv(header=True)
    # Only (re)write the serve-metrics file when the serve-path benches
    # actually ran — a partial `--only fig6` iteration must not clobber the
    # tracked BENCH_serve.json with an empty one. (bench_multi_model and
    # bench_eviction own BENCH_multi_model.json / BENCH_eviction.json and
    # write them themselves.)
    if args.json and any(b in metrics["benches"]
                         for b in ("kernel_probe", "serve_path")):
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total {time.perf_counter()-t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
