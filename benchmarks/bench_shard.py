"""Scale-out: the bucket-sharded cache tier vs shard count (DESIGN.md §11).

The sharding story is a CAPACITY story: each device holds a constant
per-shard slab (the per-device memory budget), so the tier's aggregate
resident capacity grows linearly with the shard count while the probe
stays one fused dispatch with an O(B) one-hot combine — never cache-row
traffic. This bench holds the per-shard geometry fixed, sweeps shard
count 1/2/4/8, and measures:

* ``req_per_s`` — aggregate serve_many throughput on a Zipf replay
  (host-CPU shards share one physical CPU, so this tracks dispatch +
  collective overhead, not real scaling);
* ``aggregate_slots`` / ``resident_bytes_per_device`` — the capacity
  axis: total table slots grow with shards, per-device bytes do not;
* ``hit_rate`` — the payoff: the same stream against the larger
  aggregate table holds more of the working set;
* ``parity`` — "exact" iff a sharded serve_many returns byte-identical
  outputs/counters/state to the single-device oracle on a checked run.

Device count is locked at first jax init, so the measurement runs in ONE
re-executed subprocess with 8 forced host devices; the parent collects
its JSON. Writes ``BENCH_shard.json`` (schema ``ercache-bench-shard/1``),
asserted and rendered by CI.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_shard.json")

SHARD_COUNTS = (1, 2, 4, 8)


def _worker(quick: bool) -> dict:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import server as srv_lib
    from repro.core.config import CacheConfig
    from repro.core.hashing import Key64

    assert len(jax.devices()) >= max(SHARD_COUNTS), jax.devices()
    rng = np.random.default_rng(0)
    B, S, D = (128, 16, 16) if quick else (256, 32, 32)
    chunks = 2 if quick else 4
    nb_per_shard = 1 << 8 if quick else 1 << 10
    ways, users, zipf_a = 4, 20000, 1.1

    def tower(params, feats):
        return feats @ params

    params = jnp.asarray(rng.normal(size=(D, D)), jnp.float32)

    def stage(lo_step):
        ids = rng_stream[lo_step:lo_step + S]
        k = Key64.from_int(ids.astype(np.int64))
        f = jnp.asarray(
            np.take(feat_table, ids % 997, axis=0), jnp.float32)
        now = (jnp.arange(S, dtype=jnp.int32) + lo_step + 1) * 100
        return k, f, now

    rng_stream = (rng.zipf(zipf_a, size=(chunks * SHARD_COUNTS.__len__()
                                         * S + S, B)) % users)
    feat_table = rng.normal(size=(997, D)).astype(np.float32)

    def eq_tree(a, b):
        la, ta = jax.tree_util.tree_flatten(a)
        lb, tb = jax.tree_util.tree_flatten(b)
        return ta == tb and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(la, lb))

    # parity probe: one fixed small config, sharded vs oracle, bit-exact
    pcfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=256, ways=4,
                       value_dim=D, cache_ttl_ms=60000,
                       failover_ttl_ms=600000, eviction="lru")
    pk, pf, pnow = stage(0)
    psrv = srv_lib.CachedEmbeddingServer(cfg=pcfg, tower_fn=tower,
                                         miss_budget=B)
    pst = srv_lib.init_server_state(pcfg, writebuf_capacity=B * 4)
    want = psrv.jit_serve_many(params, pst, pk, pf, pnow, flush_every=1)

    out = {}
    for n_shards in SHARD_COUNTS:
        mesh = (Mesh(np.array(jax.devices()[:n_shards]), ("shard",))
                if n_shards > 1 else None)
        # capacity scaling: constant per-shard slab, growing global table
        nb = nb_per_shard * n_shards
        cfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=nb,
                          ways=ways, value_dim=D, cache_ttl_ms=10 ** 8,
                          failover_ttl_ms=10 ** 9, eviction="lru")
        srv = srv_lib.CachedEmbeddingServer(cfg=cfg, tower_fn=tower,
                                            miss_budget=B, mesh=mesh)
        state = srv_lib.init_server_state(cfg, writebuf_capacity=B * 4,
                                          mesh=mesh)
        table_bytes = sum(
            x.nbytes for x in jax.tree_util.tree_leaves(
                (state.direct, state.failover)))

        # warmup chunk compiles serve_many; timed chunks reuse it
        k, f, now = stage(0)
        state, _, _ = srv.jit_serve_many(params, state, k, f, now,
                                         flush_every=1, collect=False)
        hits = requests = 0
        t0 = time.perf_counter()
        for c in range(chunks):
            k, f, now = stage((c + 1) * S)
            state, acc, _ = srv.jit_serve_many(params, state, k, f, now,
                                               flush_every=1, collect=False)
            acc = jax.device_get(acc)  # erlint: allow[ER002] — one fetch per chunk
            hits += int(acc["direct_hits"])
            requests += int(acc["requests"])
        wall = time.perf_counter() - t0

        # parity on this shard count (n_shards=1 trivially exact: same path)
        if mesh is not None:
            ssrv = srv_lib.CachedEmbeddingServer(cfg=pcfg, tower_fn=tower,
                                                 miss_budget=B, mesh=mesh)
            sst = srv_lib.init_server_state(pcfg, writebuf_capacity=B * 4,
                                            mesh=mesh)
            got = ssrv.jit_serve_many(params, sst, pk, pf, pnow,
                                      flush_every=1)
            parity = "exact" if eq_tree(want, got) else "MISMATCH"
        else:
            parity = "exact"
        out[str(n_shards)] = {
            "n_buckets": nb,
            "aggregate_slots": nb * ways + cfg.resolved_failover_n_buckets()
            * cfg.resolved_failover_ways(),
            "resident_bytes_per_device": table_bytes // n_shards,
            "req_per_s": round(requests / max(wall, 1e-9), 1),
            "hit_rate": round(hits / max(requests, 1), 4),
            "parity": parity,
        }
    return out


def run(report):
    quick = getattr(common, "QUICK", False)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{max(SHARD_COUNTS)}")
    env["ERCACHE_BENCH_SHARD_WORKER"] = "quick" if quick else "full"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard"], env=env, cwd=root,
        capture_output=True, text=True, timeout=1200)
    if res.returncode != 0:
        raise RuntimeError(f"shard worker failed:\n{res.stderr[-2000:]}")
    shards = json.loads(res.stdout.strip().splitlines()[-1])

    for n, m in shards.items():
        report.add(f"shard_serve_x{n}", 0.0,
                   f"req_per_s={m['req_per_s']}"
                   f"_slots={m['aggregate_slots']}"
                   f"_hit={m['hit_rate']:.3f}_parity={m['parity']}")

    metrics = {
        "schema": "ercache-bench-shard/1",
        "quick": quick,
        "shard_counts": list(SHARD_COUNTS),
        "shards": shards,
        "parity_all_exact": all(m["parity"] == "exact"
                                for m in shards.values()),
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}")
    return None


if __name__ == "__main__":
    quick = os.environ.get("ERCACHE_BENCH_SHARD_WORKER", "full") == "quick"
    print(json.dumps(_worker(quick)))
