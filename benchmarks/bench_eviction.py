"""Eviction-policy payoff: access-bumped LRU vs TTL-priority under re-access.

The §3.3 policy switch is only worth its plumbing if the two victim orders
produce different hit rates on a realistic stream. This bench drives the
REAL serve path (serve_step → touch buffer → flush, jnp backend) with a
Zipf re-access workload at capacity pressure ≥ 1 (distinct keys ≥ cache
slots) and a TTL far beyond the horizon, so entries never expire and the
arms isolate pure victim-order behavior:

* **ttl** — TTL-priority: with nothing expired, victims are oldest-WRITE.
  Hot keys are written once and then only ever read (no read-refresh,
  paper §3.2), so their write age grows until the policy evicts them.
* **lru** — LRU-timestamp over ``max(write_ts, last_access_ts)``: every
  hit's deferred touch keeps hot keys young, so eviction lands on the
  Zipf tail instead.

Steady-state direct hit rate is measured over the second half of the
rounds. Writes ``BENCH_eviction.json`` (schema ``ercache-bench-evict/1``)
with the per-pressure LRU/TTL gap — the trajectory file for this axis.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import server as S
from repro.core.config import CacheConfig
from repro.core.hashing import Key64

DIM = 16
ZIPF_A = 1.2
HOUR_MS = 3_600_000
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_eviction.json")


def _tower(params, feats):
    return feats @ params


def _steady_hit_rate(eviction: str, n_buckets: int, ways: int,
                     pressure: float, batch: int, rounds: int,
                     seed: int = 0) -> float:
    """Serve `rounds` Zipf batches end to end; hit rate of the last half."""
    n_keys = max(int(n_buckets * ways * pressure), 1)
    cfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=n_buckets,
                      ways=ways, value_dim=DIM, cache_ttl_ms=HOUR_MS,
                      failover_ttl_ms=2 * HOUR_MS, eviction=eviction)
    srv = S.CachedEmbeddingServer(cfg=cfg, tower_fn=_tower,
                                  miss_budget=batch)
    state = S.init_server_state(cfg, writebuf_capacity=2 * batch)
    params = jnp.eye(DIM, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    hits = reqs = 0
    for r in range(rounds):
        ids = rng.zipf(ZIPF_A, size=batch).astype(np.int64) % n_keys
        keys = Key64.from_int(ids)
        feats = jnp.asarray(rng.standard_normal((batch, DIM)), jnp.float32)
        t = r * 2000
        res = srv.jit_serve_step(params, state, keys, feats, t)
        state = res.state
        if r >= rounds // 2:
            s = jax.device_get(res.stats)  # erlint: allow[ER002] — one fetch per dispatch
            hits += int(s["direct_hits"])
            reqs += int(s["requests"])
        state = srv.jit_flush(state, t)
    return hits / max(reqs, 1)


def run(report):
    quick = getattr(common, "QUICK", False)
    n_buckets = 64 if quick else 256
    ways = 4
    batch = 256 if quick else 512
    rounds = 16 if quick else 32
    pressures = [2.0] if quick else [1.0, 2.0, 4.0]

    per_pressure = {}
    for p in pressures:
        h_ttl = _steady_hit_rate("ttl", n_buckets, ways, p, batch, rounds)
        h_lru = _steady_hit_rate("lru", n_buckets, ways, p, batch, rounds)
        gap = h_lru - h_ttl
        per_pressure[str(p)] = {
            "hit_rate_ttl": round(h_ttl, 4),
            "hit_rate_lru": round(h_lru, 4),
            "lru_gap": round(gap, 4),
        }
        report.add(f"eviction_lru_vs_ttl_p{p:g}", 0.0,
                   f"lru={h_lru:.4f}_ttl={h_ttl:.4f}_gap={gap:+.4f}")

    metrics = {
        "schema": "ercache-bench-evict/1",
        "quick": quick,
        "zipf_a": ZIPF_A,
        "n_buckets": n_buckets,
        "ways": ways,
        "capacity": n_buckets * ways,
        "batch": batch,
        "rounds": rounds,
        "per_pressure": per_pressure,
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}")
    # BENCH_eviction.json is this axis's single source of truth (same
    # rationale as bench_multi_model): don't duplicate into BENCH_serve.json.
    return None
