"""§Roofline summary: reads the dry-run artifact (launch/dryrun.py output)
and prints the three-term table per (arch × shape × mesh)."""
from __future__ import annotations

import json
import os

from benchmarks.common import Report

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "experiments", "dryrun_results.json")


def run(report: Report | None = None, path: str = RESULTS) -> dict:
    report = report or Report()
    if not os.path.exists(path):
        report.add("roofline_missing", 0.0,
                   "run: python -m repro.launch.dryrun --all [--multi-pod]")
        return {}
    with open(path) as f:
        results = json.load(f)
    ok = {k: v for k, v in results.items() if v.get("ok")}
    for key, v in sorted(ok.items()):
        if "singlepod" not in key:
            continue
        name = f"roofline_{v['arch']}_{v['shape']}"
        report.add(name, 0.0,
                   f"compute={v['compute_s_term']*1e3:.2f}ms "
                   f"memory={v['memory_s_term']*1e3:.2f}ms "
                   f"collective={v['collective_s_term']*1e3:.2f}ms "
                   f"dominant={v['dominant']} "
                   f"useful={100*v['useful_flops_ratio']:.0f}% "
                   f"hbm={v['memory_stats']['peak_estimate_gb']}GB/dev")
    n_multi = sum(1 for k in ok if "multipod" in k)
    report.add("roofline_cells_ok", 0.0,
               f"{sum(1 for k in ok if 'singlepod' in k)}/40 single-pod, "
               f"{n_multi}/40 multi-pod")
    return ok


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
