"""Chaos engine bench: the degradation ledger under the preset multi-fault
scenarios, CI-asserted (DESIGN.md §14).

Three arms:

* **scenarios** — each ``launch/serve.py --chaos`` preset (``incident``,
  ``cascade``, ``rolling``) replayed end to end through chunked
  ``serve_many`` dispatches on the compiled fault schedule; the ledger's
  SLA-served rate must clear its floor (0.99 single-fault, 0.95 for the
  compounding cascade), recovery must land within
  ``RECOVERY_MAX_WINDOWS`` tail windows of the faults clearing, and the
  conservation identity (requests == direct + computed + failover +
  defaults) must hold in EVERY window;
* **parity** — serving a stream on an all-quiet ``benign_schedule`` must
  be bit-exact with ``chaos=None`` (embeddings, counters, final cache
  image) on both cache backends: the chaos hooks cost nothing when off;
* **hedging** — the ``StragglerHedger`` p99 with/without hedging and its
  extra-compute cost, reported from the scenario runs.

Writes ``BENCH_chaos.json`` (schema ``ercache-bench-chaos/1``), asserted
and rendered by CI.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Report
from repro.core import server as srv_lib
from repro.core.config import CacheConfig, MINUTE_MS
from repro.core.hashing import Key64
from repro.ft import chaos as chaos_lib
from repro.launch.serve import run_serving_chaos

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_chaos.json")

SLA_FLOORS = {"incident": 0.99, "cascade": 0.95, "rolling": 0.99}
RECOVERY_MAX_WINDOWS = 2     # recovery bound: hit rate back within tol_pp
DIM = 16


def _tower(params, feats):
    return feats @ params


def _parity_probe(backend: str) -> str:
    """Benign schedule vs chaos=None on the multi-model tier: outputs,
    counters, and the final cache image must agree bit for bit."""
    cfgs = tuple(CacheConfig(
        model_id=m + 1, model_type="ctr", n_buckets=32, ways=4,
        value_dim=DIM, cache_ttl_ms=5 * MINUTE_MS,
        failover_ttl_ms=30 * MINUTE_MS, infer_budget_per_step=32.0,
        backend=backend) for m in range(2))
    n_steps, batch, n_users = 6, 16, 30
    rng = np.random.default_rng(11)
    ids = rng.integers(0, n_users, size=(n_steps, batch))
    flat = Key64.from_int(ids.reshape(-1).astype(np.int64))
    keys = Key64(hi=flat.hi.reshape(n_steps, batch),
                 lo=flat.lo.reshape(n_steps, batch))
    feats = jnp.asarray(
        (ids[..., None] * 31 + np.arange(DIM)) % 97, jnp.float32) / 97.0
    nows = jnp.asarray((np.arange(n_steps) + 1) * 1000, jnp.int32)
    slots = jnp.asarray(ids % 2, jnp.int32)
    params = jnp.eye(DIM, dtype=jnp.float32)

    def serve(chaos):
        srv = srv_lib.MultiModelServer(cfgs=cfgs, tower_fn=_tower,
                                       miss_budget=batch)
        st = srv_lib.init_multi_server_state(cfgs, writebuf_capacity=128)
        return srv.serve_many(params, st, slots, keys, feats, nows, None,
                              chaos)

    st_a, acc_a, ys_a = serve(None)
    st_b, acc_b, ys_b = serve(chaos_lib.benign_schedule(n_steps, batch,
                                                        n_models=2))
    a = jax.device_get(acc_a)  # erlint: allow[ER002] — the parity fetch
    b = jax.device_get(acc_b)  # erlint: allow[ER002] — the parity fetch
    ok = all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
             for k in a)
    ok = ok and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(ys_a[:2], ys_b[:2]))
    ok = ok and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(srv_lib.cache_image(st_a)),
                        jax.tree_util.tree_leaves(srv_lib.cache_image(st_b))))
    return "exact" if ok else "MISMATCH"


def run(report: Report | None = None) -> None:
    report = report or Report()
    quick = common.QUICK
    kw = (dict(steps=120, batch=128, users=128) if quick else {})

    scenarios = {}
    floors_ok = True
    for name in chaos_lib.PRESETS:
        out = run_serving_chaos(scenario=name, log=lambda *a, **k: None,
                                **kw)
        floor = SLA_FLOORS[name]
        rec = out["recovery"]
        ok = (out["sla_served_rate"] >= floor
              and out["conservation_ok"]
              and rec["recovered"]
              and rec["recovered_after_windows"] <= RECOVERY_MAX_WINDOWS)
        floors_ok = floors_ok and ok
        out["sla_floor"] = floor
        out["floor_ok"] = ok
        scenarios[name] = out
        report.add(f"chaos_{name}_sla", 0.0,
                   f"served={out['sla_served_rate']:.4f} "
                   f"(floor {floor:g} ok={ok}) "
                   f"fo={out['failover_serves']} "
                   f"defaults={out['fallbacks']} retries={out['retries']}")
        report.add(f"chaos_{name}_recovery", 0.0,
                   f"{rec['recovered_after_windows']}/{rec['tail_windows']}"
                   f" windows (bound {RECOVERY_MAX_WINDOWS})")
        h = out["hedging"]
        report.add(f"chaos_{name}_hedging", 0.0,
                   f"p99={h['p99_ms']}ms vs {h['p99_unhedged_ms']}ms "
                   f"unhedged (+{h['extra_compute_frac']:.1%} compute)")

    parity = {}
    for backend in ("jnp", "pallas"):
        try:
            parity[backend] = _parity_probe(backend)
        except Exception as e:  # noqa: BLE001 — report, don't crash the run
            parity[backend] = f"ERROR: {type(e).__name__}"
        report.add(f"chaos_parity_{backend}", 0.0, parity[backend])

    metrics = {
        "schema": "ercache-bench-chaos/1",
        "quick": quick,
        "sla_floors": SLA_FLOORS,
        "recovery_max_windows": RECOVERY_MAX_WINDOWS,
        "floors_ok": floors_ok,
        "parity": parity,
        "conservation_ok": all(s["conservation_ok"]
                               for s in scenarios.values()),
        "scenarios": scenarios,
    }
    if common.WRITE_JSON:
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return None     # owns its JSON; nothing to merge into benchmarks.json


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
