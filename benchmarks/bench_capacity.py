"""BEYOND the paper: capacity vs hit rate for the in-HBM cache.

Meta's ERCache lives in an elastic memcache tier, so the paper only studies
TTL. Our TPU-native redesign (DESIGN.md §2) bounds the cache by device HBM,
making capacity a first-class knob: this experiment runs the REAL
set-associative CacheState over the calibrated request stream and measures
hit rate vs slot count at a fixed 1 h TTL — i.e. how much HBM the paper's
89.7% @ 1 h actually requires, and how gracefully the 8-way TTL-eviction
design degrades under slot pressure (conflict evictions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core import cache as C
from repro.core.hashing import Key64
from repro.data.access_patterns import (FIG6_KNOTS, InterArrivalDist,
                                        StreamConfig, generate_stream_fast,
                                        simulate_hit_rate)

TTL_MS = 3_600_000
DIM = 8
BATCH = 32   # a batch spans ~20 s of sim time — coarser windows alias
             # consecutive same-user accesses into one lookup and fake misses


def run(report: Report | None = None, n_users: int = 2500,
        horizon_h: float = 30.0) -> dict:
    report = report or Report()
    cfg = StreamConfig(n_users=n_users, horizon_s=horizon_h * 3600, seed=13)
    times, users = generate_stream_fast(cfg, InterArrivalDist(FIG6_KNOTS))
    warmup_ms = int(8 * 3.6e6)

    # infinite-capacity upper bound from the exact simulator
    inf_hit = simulate_hit_rate(times, users, TTL_MS,
                                measure_from_ms=warmup_ms)
    report.add("capacity_hit_ttl1h_infinite", 0.0,
               f"hit={inf_hit:.3f} (paper Fig.6: 0.897)")

    out = {"infinite": inf_hit}
    # capacity as a fraction of the active-user population
    for n_buckets, ways in ((64, 4), (128, 8), (512, 8), (2048, 8)):
        slots = n_buckets * ways

        @jax.jit
        def step(state, hi, lo, now):
            keys = Key64(hi=hi, lo=lo)
            res = C.lookup(state, keys, now, TTL_MS)
            vals = jnp.zeros((hi.shape[0], DIM))
            state = C.insert(state, keys, vals, now, TTL_MS,
                             write_mask=~res.hit)
            return state, res.hit

        state = C.init_cache(n_buckets, ways, DIM)
        hits = total = 0
        for lo_i in range(0, len(users) - BATCH + 1, BATCH):
            ids = users[lo_i:lo_i + BATCH]
            now = int(times[lo_i + BATCH - 1])
            k = Key64.from_int(ids)
            state, h = step(state, k.hi, k.lo, now)
            if now >= warmup_ms:
                hits += int(np.asarray(h).sum())
                total += BATCH
        rate = hits / max(total, 1)
        frac = slots / n_users
        report.add(f"capacity_hit_ttl1h_slots{slots}", 0.0,
                   f"hit={rate:.3f} slots/user={frac:.2f} "
                   f"loss_vs_inf={100*(inf_hit-rate):.1f}pp")
        out[slots] = rate
    return out


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
