"""Multi-model tier: ONE dispatch for the whole registry vs a per-model loop.

The paper's headline deployment shape — 30+ ranking models behind one cache
tier, each with customized settings — reproduced as the stacked
MultiCacheState (DESIGN.md §5). This bench measures what the stacking buys:

* **single dispatch** — a mixed-model batch of B queries over M models is
  probed (direct + failover, per-model TTLs) by ONE ``lookup_dual_multi``
  call;
* **per-model loop** — the same B queries served the pre-stacking way:
  M separate ``lookup_dual`` dispatches, one per model, each over that
  model's B/M sub-batch against its own tables.

Also runs a short warm serve loop and reports the per-model hit-rate
breakdown (the Table 2 shape). Writes ``BENCH_multi_model.json`` and
returns the same metrics dict for ``BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as C
from repro.core import server as S
from repro.core.config import multi_model_tier_configs
from repro.core.hashing import Key64

DIM = 64
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_multi_model.json")


def _tower(params, feats):
    return feats @ params


def _warm_state(cfgs, rng, batch, rounds=4):
    """A few serve+flush rounds so the probes see a realistic hit mix."""
    srv = S.MultiModelServer(cfgs=tuple(cfgs), tower_fn=_tower,
                             miss_budget=batch, backend="jnp")
    state = S.init_multi_server_state(cfgs, writebuf_capacity=batch * 2)
    params = jnp.eye(DIM, dtype=jnp.float32)
    M = srv.n_models
    for r in range(rounds):
        ids = rng.zipf(1.3, size=batch).astype(np.int64) % 4096
        keys = Key64.from_int(ids)
        slots = jnp.asarray((np.arange(batch) + r) % M, jnp.int32)
        feats = jnp.asarray(rng.standard_normal((batch, DIM)), jnp.float32)
        res = srv.serve_step(params, state, slots, keys, feats, r * 30_000)
        state = srv.flush(res.state, r * 30_000)
    return srv, state, params


def run(report):
    quick = getattr(common, "QUICK", False)
    B = 512 if quick else 2048
    n_buckets = 1 << 8 if quick else 1 << 10
    n_models = 4 if quick else 8
    rng = np.random.default_rng(0)

    cfgs = multi_model_tier_configs(value_dim=DIM,
                                    n_buckets=n_buckets)[:n_models]
    srv, state, params = _warm_state(cfgs, rng, B)
    policy = srv.policy
    M = len(cfgs)
    assert B % M == 0

    ids = rng.zipf(1.3, size=B).astype(np.int64) % 4096
    keys = Key64.from_int(ids)
    slots = jnp.asarray(np.arange(B) % M, jnp.int32)
    now = 5 * 30_000

    # ------------------------------------------- arm A: single dispatch
    single = jax.jit(lambda d, f, s, k: C.lookup_dual_multi(
        d, f, policy, s, k, now, backend="jnp"))
    us_single = common.time_us(single, state.direct, state.failover, slots,
                               keys)

    # ------------------------------------------- arm B: per-model loop
    # The pre-stacking deployment: each model owns its tables; its B/M
    # sub-batch is a separate dual-probe dispatch. Views and sub-batches
    # are prepared outside the timed region (a real per-model deployment
    # holds them that way permanently).
    slots_np = np.arange(B) % M
    per_model = []
    for m, cfg in enumerate(cfgs):
        mask = slots_np == m
        sub_keys = Key64(hi=keys.hi[np.flatnonzero(mask)],
                         lo=keys.lo[np.flatnonzero(mask)])
        d_view = state.direct.model_view(m, cfg.n_buckets)
        f_view = state.failover.model_view(
            m, cfg.resolved_failover_n_buckets())
        fn = jax.jit(lambda d, f, k, _ttl=cfg.cache_ttl_ms,
                     _fttl=cfg.failover_ttl_ms: C.lookup_dual(
                         d, f, k, now, _ttl, _fttl, backend="jnp"))
        per_model.append((fn, d_view, f_view, sub_keys))

    def loop_all():
        outs = [fn(d, f, k) for fn, d, f, k in per_model]
        return [o for pair in outs for o in pair]

    us_loop = common.time_us(loop_all)

    speedup = us_loop / us_single
    report.add(f"multi_single_dispatch_B{B}_M{M}", us_single,
               f"{B / (us_single * 1e-6):.0f}_probes_per_s")
    report.add(f"multi_per_model_loop_B{B}_M{M}", us_loop,
               f"single_dispatch_speedup={speedup:.2f}x")

    # ------------------------------------- per-model hit-rate breakdown
    res_d, _ = C.lookup_dual_multi(state.direct, state.failover, policy,
                                   slots, keys, now, backend="jnp")
    hit = np.asarray(res_d.hit)
    per_model_hit_rate = {
        str(cfg.model_id): round(float(hit[slots_np == m].mean()), 4)
        for m, cfg in enumerate(cfgs)
    }

    metrics = {
        "schema": "ercache-bench-multi/1",
        "quick": quick,
        "batch": B,
        "n_models": M,
        "n_buckets_per_model": n_buckets,
        "single_dispatch_us": us_single,
        "per_model_loop_us": us_loop,
        "single_dispatch_speedup": speedup,
        "per_model_hit_rate": per_model_hit_rate,
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}")
    # BENCH_multi_model.json is the single source of truth for these
    # numbers — returning them would duplicate them into BENCH_serve.json,
    # where a partial --only rerun could leave the two copies disagreeing.
    return None
