"""Fig. 10 at paper scale ON DEVICE: the regional drain test, fast.

The host-loop reproduction (bench_drain.py) routes one event at a time
through python and dispatches per-region micro-batches — faithful, but
throughput-bound by the host. This bench replays the SAME scenario shape
(13 regions, sticky routing, one region drained for hours 21-26 of a
30-hour horizon, warm-up excluded) through ``core/regional.py``: regions
stacked as a leading axis over the cache tier, routing + drain mask on
device, whole chunks of serve steps per dispatch.

Three claims, all CI-asserted:

* **drain stability** — the global hit rate during the drain window
  stays within ``BAND_PP`` of the outside-drain mean (the Fig. 10
  claim), and the drained region receives exactly 0 requests;
* **throughput** — the device path beats the host-loop harness replay
  (req/s, compile excluded via a warm-up chunk);
* **parity** — a small R=2 replay with a mid-stream drain/undrain is
  bit-exact vs the numpy ``RegionRouter`` oracle (the same lock
  tests/test_region_parity.py holds at R ∈ {2, 4, 13}).

Writes ``BENCH_regions.json`` (schema ``ercache-bench-regions/1``),
asserted and rendered by CI.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Report
from repro.core import regional as rg_lib
from repro.core import server as srv_lib
from repro.core.config import CacheConfig, HOUR_MS, MINUTE_MS
from repro.core.hashing import Key64
from repro.core.ratelimit import RegionalRateLimiter
from repro.core.regions import DrainTestHarness, RegionRouter
from repro.data.access_patterns import (FIG6_KNOTS, InterArrivalDist,
                                        StreamConfig, generate_stream_fast)

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_regions.json")

N_REGIONS = 13
DIM = 16
LOCALITY = 0.98
DRAIN_REGION = 3
WARM_H, DRAIN_LO_H, DRAIN_HI_H, HORIZON_H = 6.0, 21.0, 26.0, 30.0
BAND_PP = 5.0     # CI band: |in-drain dip| tolerated ("hit rate stable")


def _tower(params, feats):
    return feats @ params


def _keys(ids):
    return Key64.from_int(np.asarray(ids, np.int64))


def _feats(ids):
    return jnp.asarray(np.asarray(ids)[:, None] * np.ones(DIM), jnp.float32)


def _device_drain(times_ms, uids, n_users, batch, chunk_steps, cfg):
    """The drain scenario through chunked serve_many dispatches; returns
    the per-chunk hit-rate curve + phase means + throughput."""
    server = rg_lib.RegionalServer(
        cfgs=(cfg,), n_regions=N_REGIONS, n_users=n_users,
        tower_fn=_tower, miss_budget=batch, locality=LOCALITY, seed=1)
    params = jnp.eye(DIM)
    # full chunks only: one compiled shape, so the warm-up below covers
    # every timed dispatch
    n_batches = (len(uids) // batch // chunk_steps) * chunk_steps

    def batch_at(t_h):
        return int(np.searchsorted(times_ms, t_h * 3.6e6) // batch
                   // chunk_steps) * chunk_steps

    warm_b = batch_at(WARM_H)
    drain_lo, drain_hi = batch_at(DRAIN_LO_H), batch_at(DRAIN_HI_H)
    events = [(drain_lo, "drain", DRAIN_REGION)]
    if drain_hi < n_batches:
        events.append((drain_hi, "undrain", DRAIN_REGION))
    drained_all, epoch_all = rg_lib.stage_drain_schedule(
        n_batches, N_REGIONS, events)
    ebase_all = rg_lib.event_bases(0, n_batches, batch)

    def stage(lo, n):
        ids = uids[lo * batch:(lo + n) * batch].reshape(n, batch)
        flat = _keys(ids.reshape(-1))
        keys = Key64(hi=flat.hi.reshape(n, batch),
                     lo=flat.lo.reshape(n, batch))
        feats = _feats(ids.reshape(-1)).reshape(n, batch, DIM)
        nows = jnp.asarray(
            times_ms[(np.arange(lo, lo + n) + 1) * batch - 1], jnp.int32)
        return jnp.asarray(ids, jnp.int32), keys, feats, nows

    # warm-up: compile the chunk dispatch on a throwaway state so the
    # timed replay measures steady-state throughput, not XLA
    wids, wkeys, wfeats, wnows = stage(0, chunk_steps)
    wstate, _, _ = server.jit_serve_many(
        params, server.init_state(writebuf_capacity=batch * 4), wids,
        jnp.zeros((chunk_steps, batch), jnp.int32), wkeys, wfeats, wnows,
        drained_all[:chunk_steps], epoch_all[:chunk_steps],
        ebase_all[:chunk_steps], flush_every=1, collect=False)
    del wstate

    state = server.init_state(writebuf_capacity=batch * 4)
    curve = []
    drained_load = 0
    requests = 0
    t0 = time.perf_counter()
    for lo in range(0, n_batches, chunk_steps):
        ids, keys, feats, nows = stage(lo, chunk_steps)
        state, acc, _ = server.jit_serve_many(
            params, state, ids, jnp.zeros((chunk_steps, batch), jnp.int32),
            keys, feats, nows, drained_all[lo:lo + chunk_steps],
            epoch_all[lo:lo + chunk_steps], ebase_all[lo:lo + chunk_steps],
            flush_every=1, collect=False)
        s = jax.device_get(acc)  # erlint: allow[ER002] — one fetch per chunk
        req, hits = int(s["requests"]), int(s["direct_hits"])
        requests += req
        load = np.asarray(s["per_model_requests"], np.int64)
        if drain_lo <= lo < drain_hi:
            drained_load += int(load.reshape(N_REGIONS, -1)
                                .sum(axis=1)[DRAIN_REGION])
        curve.append((lo, hits / max(req, 1)))
    wall = time.perf_counter() - t0

    hr = np.asarray([h for _, h in curve])
    los = np.asarray([lo for lo, _ in curve])
    warm = los >= warm_b
    in_drain = warm & (los >= drain_lo) & (los < drain_hi)
    outside = warm & ~in_drain
    mean_out = float(hr[outside].mean()) if outside.any() else float("nan")
    mean_in = float(hr[in_drain].mean()) if in_drain.any() else float("nan")
    return {
        "hit_rate_curve": [round(h, 4) for h in hr.tolist()],
        "mean_out": round(mean_out, 4), "mean_in": round(mean_in, 4),
        "dip_pp": round((mean_out - mean_in) * 100, 2),
        "drained_load": drained_load,
        "requests": requests, "wall_s": round(wall, 2),
        "req_per_s": round(requests / max(wall, 1e-9), 1),
        "drain_batches": [drain_lo, drain_hi], "n_batches": n_batches,
    }


def _host_baseline(times_ms, uids, batch, cfg, max_events):
    """Replay a stream prefix through the python-loop DrainTestHarness
    (per-event routing, per-region micro-batches) — the throughput bar
    the device path must clear. Correctness of the host path itself is
    bench_drain's job; the rate limiter is left effectively open here so
    the measurement is pure replay speed."""
    times_ms, uids = times_ms[:max_events], uids[:max_events]
    servers, states = [], []
    for _ in range(N_REGIONS):
        servers.append(srv_lib.CachedEmbeddingServer(
            cfg=cfg, tower_fn=_tower, miss_budget=batch))
        states.append(srv_lib.init_server_state(
            cfg, writebuf_capacity=batch * 2))
    harness = DrainTestHarness(
        servers=servers, states=states, params=jnp.eye(DIM),
        router=RegionRouter(n_regions=N_REGIONS, locality=LOCALITY, seed=1),
        limiter=RegionalRateLimiter.uniform(range(N_REGIONS),
                                            rate_per_s=1e9, burst_s=1.0),
        feature_fn=lambda ids, now: _feats(ids),
        key_fn=_keys, batch=batch, flush_every_ms=30_000)
    t0 = time.perf_counter()
    harness.run(uids, times_ms, bucket_ms=int(1 * 3.6e6))
    wall = time.perf_counter() - t0
    return {"requests": len(uids), "wall_s": round(wall, 2),
            "req_per_s": round(len(uids) / max(wall, 1e-9), 1)}


def _parity_probe():
    """R=2, mid-stream drain/undrain: device replay vs the sequential
    numpy-oracle routing + per-region serving — counters and the home
    table must agree exactly."""
    cfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=32, ways=4,
                      value_dim=DIM, cache_ttl_ms=5 * MINUTE_MS,
                      failover_ttl_ms=20 * MINUTE_MS)
    n_regions, n_steps, batch, n_users = 2, 8, 16, 50
    rng = np.random.default_rng(7)
    uids = rng.integers(0, n_users, size=(n_steps, batch)).astype(np.int32)
    nows = (np.arange(n_steps) * 10_000).astype(np.int32)
    events = [(2, "drain", 1), (5, "undrain", 1)]

    server = rg_lib.RegionalServer(
        cfgs=(cfg,), n_regions=n_regions, n_users=n_users, tower_fn=_tower,
        miss_budget=batch, locality=0.9, seed=5)
    drained, epoch = rg_lib.stage_drain_schedule(n_steps, n_regions, events)
    flat = _keys(uids.reshape(-1))
    keys = Key64(hi=flat.hi.reshape(n_steps, batch),
                 lo=flat.lo.reshape(n_steps, batch))
    feats = _feats(uids.reshape(-1)).reshape(n_steps, batch, DIM)
    final, acc, _ = server.jit_serve_many(
        jnp.eye(DIM), server.init_state(writebuf_capacity=64),
        jnp.asarray(uids), jnp.zeros((n_steps, batch), jnp.int32), keys,
        feats, jnp.asarray(nows), drained, epoch,
        rg_lib.event_bases(0, n_steps, batch))
    acc = jax.device_get(acc)  # erlint: allow[ER002] — the parity fetch

    router = RegionRouter(n_regions=n_regions, locality=0.9, seed=5,
                          sampler="hash")
    by_step = {}
    for step, op, reg in events:
        by_step.setdefault(step, []).append((op, reg))
    osrv = srv_lib.MultiModelServer(cfgs=(cfg,), tower_fn=_tower,
                                    miss_budget=batch)
    ostates = [srv_lib.init_multi_server_state((cfg,), writebuf_capacity=64)
               for _ in range(n_regions)]
    oc = np.zeros((n_regions, 2), np.int64)          # requests, hits
    for s in range(n_steps):
        for op, reg in by_step.get(s, ()):
            getattr(router, op)(reg)
        regions = np.array([router.route(int(u)) for u in uids[s]])
        for r in range(n_regions):
            idx = np.flatnonzero(regions == r)
            if idx.size == 0:
                continue
            res = osrv.serve_step(jnp.eye(DIM), ostates[r],
                                  jnp.zeros(idx.size, jnp.int32),
                                  _keys(uids[s][idx]), _feats(uids[s][idx]),
                                  int(nows[s]))
            ostates[r] = osrv.flush(res.state, int(nows[s]))
            oc[r, 0] += int(res.stats["requests"])
            oc[r, 1] += int(res.stats["direct_hits"])

    home = np.full((n_users,), -1, np.int32)
    for uid, h in router._home.items():
        home[uid] = h
    ok = (np.array_equal(
        np.asarray(acc["per_model_requests"], np.int64), oc[:, 0])
        and np.array_equal(
            np.asarray(acc["per_model_direct_hits"], np.int64), oc[:, 1])
        and np.array_equal(np.asarray(final.home), home))
    return "exact" if ok else "MISMATCH"


def run(report: Report | None = None) -> dict:
    report = report or Report()
    quick = common.QUICK
    n_users, batch, chunk_steps, host_cap = (
        (600, 32, 32, 3_000) if quick else (4000, 64, 64, 20_000))
    cfg = CacheConfig(model_id=1, model_type="ctr",
                      cache_ttl_ms=60 * MINUTE_MS,
                      failover_ttl_ms=2 * HOUR_MS,
                      n_buckets=1 << 12, ways=8, value_dim=DIM)
    stream_cfg = StreamConfig(n_users=n_users, horizon_s=HORIZON_H * 3600,
                              seed=4)
    times_ms, uids = generate_stream_fast(stream_cfg,
                                          InterArrivalDist(FIG6_KNOTS))

    dev = _device_drain(times_ms, uids, n_users, batch, chunk_steps, cfg)
    host = _host_baseline(times_ms, uids, batch, cfg, host_cap)
    parity = _parity_probe()

    speedup = round(dev["req_per_s"] / max(host["req_per_s"], 1e-9), 1)
    band_ok = abs(dev["dip_pp"]) <= BAND_PP
    metrics = {
        "schema": "ercache-bench-regions/1",
        "quick": quick, "n_regions": N_REGIONS, "locality": LOCALITY,
        "band_pp": BAND_PP, "band_ok": band_ok, "parity": parity,
        "device": dev, "host": host,
        "device_vs_host_speedup": speedup,
        "mean_out": dev["mean_out"], "mean_in": dev["mean_in"],
        "dip_pp": dev["dip_pp"], "drained_load": dev["drained_load"],
    }
    report.add("fig10_device_hit_rate", 0.0,
               f"out={dev['mean_out']:.3f} in={dev['mean_in']:.3f} "
               f"dip={dev['dip_pp']:.2f}pp (band ±{BAND_PP:g}pp "
               f"ok={band_ok})")
    report.add("fig10_device_drained_load", 0.0,
               f"{dev['drained_load']} requests during drain (should be 0)")
    report.add("fig10_device_req_per_s", 0.0,
               f"{dev['req_per_s']:.0f} vs host-loop "
               f"{host['req_per_s']:.0f} ({speedup:g}x)")
    report.add("fig10_device_parity_r2", 0.0, parity)
    if common.WRITE_JSON:
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return metrics


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
