"""SLA-aware admission control under overload: does the failover tier
earn its name?

The paper's failover cache exists for exactly one scenario: inference
capacity is exhausted or unavailable, and serving a STALE embedding beats
serving none (PAPER.md; the binding constraint is inference capacity, not
cache capacity). This bench drives the REAL serve path (serve_step →
admission token bucket → degradation chain → flush_dual, jnp backend)
through a capacity crunch:

1. **Warm phase** — an unconstrained server (no ``infer_budget_per_step``)
   serves a uniform re-access stream over a closed user population until
   every user has been computed at least once; ``flush_dual`` writes every
   embedding to BOTH tiers, so the failover slab ends warm. The
   steady-state misses/step of this phase is the measured inference
   demand, ``base_miss``.
2. **Crunch phase** — admission-controlled servers continue from the
   warmed state with ``infer_budget_per_step = base_miss / pressure`` for
   pressure 1 / 2 / 4 (capacity at 1×, 1/2, 1/4 of demand) and
   ``failover_ttl_relax=None`` (serve any staleness). Misses over budget
   are deferred down the chain: direct → relaxed failover → default.

The SLA claim under test (ISSUE 4 acceptance): at pressure 2 and 4 the
total served fraction (everything except default embeddings) stays
≥ 99% while default serves stay BELOW failover serves — i.e. the
degradation chain absorbs the capacity shortfall with staleness, not
with blown SLAs. Writes ``BENCH_overload.json``
(schema ``ercache-bench-overload/1``), asserted by the CI docs job.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import server as S
from repro.core.config import CacheConfig
from repro.core.hashing import Key64

DIM = 16
STEP_MS = 2_000
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_overload.json")


def _tower(params, feats):
    return feats @ params


def _cfg(n_buckets: int, users: int, budget=None) -> CacheConfig:
    # Direct TTL = one step: steady-state misses are the re-access tail.
    # Failover sized to hold the whole population at load factor ~1/8 so
    # warm entries are never evicted out from under the crunch.
    return CacheConfig(model_id=1, model_type="ctr", n_buckets=n_buckets,
                      ways=4, value_dim=DIM, cache_ttl_ms=STEP_MS,
                      failover_ttl_ms=10 * STEP_MS,
                      failover_n_buckets=max(users, 64), failover_ways=8,
                      infer_budget_per_step=budget,
                      failover_ttl_relax=None)


def _serve_rounds(srv, state, params, rng, users, batch, rounds, t0):
    """Drive `rounds` uniform-access batches; accumulate the overload
    ledger. Returns (state, totals dict, next t)."""
    tot = {k: 0 for k in ("requests", "direct_hits", "tower_inferences",
                          "admitted", "deferred", "failover_serves",
                          "fallbacks")}
    stale_sum = 0.0
    t = t0
    for _ in range(rounds):
        ids = rng.integers(0, users, size=batch).astype(np.int64)
        keys = Key64.from_int(ids)
        feats = jnp.asarray(rng.standard_normal((batch, DIM)), jnp.float32)
        res = srv.jit_serve_step(params, state, keys, feats, t)
        state = res.state
        s = jax.device_get(res.stats)  # erlint: allow[ER002] — one fetch per dispatch
        for k in tot:
            tot[k] += int(s[k])
        stale_sum += (float(s["failover_stale_ms"])
                      * int(s["failover_serves"]))
        state = srv.jit_flush(state, t)
        t += STEP_MS
    tot["mean_failover_stale_ms"] = stale_sum / max(tot["failover_serves"], 1)
    return state, tot, t


def run(report):
    quick = getattr(common, "QUICK", False)
    users = 256 if quick else 512
    n_buckets = 64 if quick else 128
    batch = 128 if quick else 256
    warm_rounds = 16 if quick else 24
    crunch_rounds = 12 if quick else 24
    pressures = [1.0, 2.0, 4.0]

    params = jnp.eye(DIM, dtype=jnp.float32)

    # warm arm: measure steady inference demand with capacity unconstrained
    cfg_w = _cfg(n_buckets, users)
    srv_w = S.CachedEmbeddingServer(cfg=cfg_w, tower_fn=_tower,
                                    miss_budget=batch)
    state = S.init_server_state(cfg_w, writebuf_capacity=2 * batch)
    rng = np.random.default_rng(0)
    state, warm, t = _serve_rounds(srv_w, state, params, rng, users, batch,
                                   warm_rounds, 0)
    base_miss = (warm["requests"] - warm["direct_hits"]) / warm_rounds

    per_pressure = {}
    for p in pressures:
        budget = max(base_miss / p, 1.0)
        cfg_p = _cfg(n_buckets, users, budget=budget)
        srv_p = S.CachedEmbeddingServer(cfg=cfg_p, tower_fn=_tower,
                                        miss_budget=batch)
        # fresh warm-up per arm (deterministic), then the capacity crunch
        st = S.init_server_state(cfg_w, writebuf_capacity=2 * batch)
        rng = np.random.default_rng(0)
        st, _, t = _serve_rounds(srv_w, st, params, rng, users, batch,
                                 warm_rounds, 0)
        st, tot, _ = _serve_rounds(srv_p, st, params, rng, users, batch,
                                   crunch_rounds, t)
        req = max(tot["requests"], 1)
        sla = 1.0 - tot["fallbacks"] / req
        per_pressure[f"{p:g}"] = {
            "budget_per_step": round(budget, 2),
            "requests": tot["requests"],
            "direct_hit_rate": round(tot["direct_hits"] / req, 4),
            "tower_inferences": tot["tower_inferences"],
            "admitted": tot["admitted"],
            "deferred": tot["deferred"],
            "failover_serves": tot["failover_serves"],
            "default_serves": tot["fallbacks"],
            "sla_served_frac": round(sla, 4),
            "failover_served_frac": round(tot["failover_serves"] / req, 4),
            "mean_failover_stale_ms": round(
                tot["mean_failover_stale_ms"], 1),
        }
        report.add(f"overload_p{p:g}", 0.0,
                   f"sla={sla:.4f}_fo={tot['failover_serves']}"
                   f"_def={tot['fallbacks']}_deferred={tot['deferred']}")

    metrics = {
        "schema": "ercache-bench-overload/1",
        "quick": quick,
        "users": users,
        "batch": batch,
        "n_buckets": n_buckets,
        "warm_rounds": warm_rounds,
        "crunch_rounds": crunch_rounds,
        "base_miss_per_step": round(base_miss, 2),
        "per_pressure": per_pressure,
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}")
    # BENCH_overload.json is this axis's single source of truth (same
    # rationale as bench_eviction): don't duplicate into BENCH_serve.json.
    return None
