"""Figs. 7–9 reproduction: ERCache serving cost — QPS, latency, bandwidth.

Our cache is in-mesh HBM (DESIGN.md §2), so the "serving cost" has two
parts: (a) measured op cost of lookup / insert / combined write on this
host (µs/call → achievable QPS per core), and (b) the paper-scale derived
accounting: write-QPS reduction from update combination (Fig. 5 / Fig. 7)
and write bandwidth at the paper's QPS (Fig. 9).

Fig. 8 (read-latency CDF) belongs to the RPC memcache design; the in-HBM
probe has no host round-trip. We report the measured in-process lookup
latency alongside the paper's p50/p99 for contrast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, time_us
from repro.core import cache as C
from repro.core import combiner as G
from repro.core.hashing import Key64

N_MODELS = 30
DIM = 64
BATCH = 1024


def run(report: Report | None = None) -> dict:
    report = report or Report()
    rng = np.random.default_rng(0)
    state = C.init_cache(1 << 14, 8, DIM)
    ids = rng.integers(0, 1 << 40, BATCH)
    keys = Key64.from_int(ids)
    vals = jnp.asarray(rng.standard_normal((BATCH, DIM)), jnp.float32)

    lookup = jax.jit(lambda s, k: C.lookup(s, k, 1000, 60_000))
    insert = jax.jit(lambda s, k, v: C.insert(s, k, v, 1000, 60_000))
    state = insert(state, keys, vals)

    us_lookup = time_us(lookup, state, keys)
    us_insert = time_us(insert, state, keys, vals)
    report.add("fig8_lookup_batch1024", us_lookup,
               f"{us_lookup/BATCH:.2f}us/req in-process "
               f"(paper RPC p50=770us p99=8470us)")
    report.add("fig7_insert_batch1024", us_insert,
               f"{us_insert/BATCH:.2f}us/req")

    # grouped write: 30 models × 64 dims in ONE insert (Fig. 5 → Fig. 7)
    spec = G.GroupSpec(members=tuple(
        G.GroupMember(f"m{i}", dim=DIM, ttl_ms=300_000)
        for i in range(N_MODELS)))
    gstate = G.init_grouped(spec, 1 << 12, 8)
    member_vals = {f"m{i}": vals for i in range(N_MODELS)}
    ginsert = jax.jit(lambda s, k: G.insert_group(
        spec, s, k, member_vals, 1000))
    us_ginsert = time_us(ginsert, gstate, keys)
    report.add("fig7_combined_write_30models", us_ginsert,
               f"{us_ginsert/BATCH:.2f}us/user-write "
               f"qps_reduction={G.write_amplification(N_MODELS, 1):.0f}x")

    # paper-scale accounting: Fig. 7 write QPS 0.93–1.63 M/s; Fig. 9 BW
    row_bytes = spec.total_dim * 4
    for qps_m in (0.93, 1.63):
        bw = qps_m * 1e6 * row_bytes / 1e9
        report.add(f"fig9_write_bw_at_{qps_m}Mqps", 0.0,
                   f"{bw:.2f}GB/s row={row_bytes}B "
                   f"(paper: 7.26-12.43GB/s)")
    return {"lookup_us_per_req": us_lookup / BATCH,
            "combined_write_us": us_ginsert / BATCH,
            "row_bytes": row_bytes}


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
