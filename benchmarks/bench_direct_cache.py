"""Table 2 reproduction: direct-cache compute savings + e2e p99 latency diff
per (predictor task × ranking stage × TTL).

Savings model (core/metrics.power_savings): a direct hit removes the user-
tower inference; with the tower consuming ``tower_share`` of per-request
power, savings = hit_rate × tower_share. Each Table-2 row gets the model
profile implied by the paper (share 0.63–0.93, distinct stream thinning per
stage — later stages see funnel-filtered traffic).

Latency model: e2e = other + tower (computed) vs other + cache_read (hit);
p99 over the simulated request stream, cache read latencies drawn from the
Fig. 8-calibrated lognormal.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Report
from repro.data.access_patterns import (FIG6_KNOTS, InterArrivalDist,
                                        StreamConfig, generate_stream_fast,
                                        simulate_hit_rate)

# (name, stage thinning, tower power share, direct TTL min, paper savings %)
# The tower power share is a per-model hardware profile the paper never
# reports directly; it is calibrated from Table 2's savings at the Fig. 6
# hit rate for each row's TTL ("power savings vary across models due to
# their distinct access patterns and model profiles", §4.2). Shares land in
# 0.63–0.99 — user-tower-dominated inference, consistent with §2's premise.
TABLE2 = [
    ("cvr_first_a", 1.00, 0.64, 5, 44),
    ("cvr_first_b", 1.00, 0.74, 5, 51),
    ("ctr_first", 1.00, 0.63, 5, 43),
    ("ctr_second", 1.00, 0.93, 5, 64),
    ("cvr_second", 1.00, 0.99, 1, 52),
]

# Fig. 8 calibration: p50 0.77 ms, p99 8.47 ms → lognormal(ln 0.77, σ)
CACHE_READ_MED_MS = 0.77
CACHE_READ_SIGMA = 1.03          # ln(8.47/0.77)/z99 = ln(11)/2.326
TOWER_MED_MS = 6.0
TOWER_SIGMA = 0.45
OTHER_MED_MS = 55.0
OTHER_SIGMA = 0.35


def _p99_diff(hit_rate: float, n: int = 200_000, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    other = rng.lognormal(np.log(OTHER_MED_MS), OTHER_SIGMA, n)
    tower = rng.lognormal(np.log(TOWER_MED_MS), TOWER_SIGMA, n)
    cache = rng.lognormal(np.log(CACHE_READ_MED_MS), CACHE_READ_SIGMA, n)
    hit = rng.uniform(size=n) < hit_rate
    with_cache = other + np.where(hit, cache, cache + tower)
    without = other + tower
    p99_w = np.percentile(with_cache, 99)
    p99_wo = np.percentile(without, 99)
    return 100.0 * (p99_w - p99_wo) / p99_wo


def run(report: Report | None = None, n_users: int = 2500,
        horizon_h: float = 72.0) -> dict:
    report = report or Report()
    dist = InterArrivalDist(FIG6_KNOTS)
    out = {}
    for name, thin, share, ttl_min, paper_sv in TABLE2:
        cfg = StreamConfig(n_users=n_users, horizon_s=horizon_h * 3600,
                           thinning=thin, seed=11)
        t_ms, users = generate_stream_fast(cfg, dist)
        hit = simulate_hit_rate(t_ms, users, ttl_min * 60_000,
                                measure_from_ms=int(24 * 3.6e6))
        savings = 100.0 * hit * share
        p99 = _p99_diff(hit, seed=hash(name) % 2**31)
        label = f"table2_{name}_ttl{ttl_min}min"
        report.add(label, 0.0,
                   f"savings={savings:.0f}% paper={paper_sv}% "
                   f"hit={hit:.3f} p99_diff={p99:+.2f}%")
        out[label] = {"savings": savings, "paper": paper_sv,
                      "hit": hit, "p99_diff": p99}
    mean_p99 = float(np.mean([v["p99_diff"] for v in out.values()]))
    report.add("table2_mean_p99_diff", 0.0,
               f"{mean_p99:+.2f}% (paper: -0.2% avg)")
    return out


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
