"""Fig. 10 reproduction: 6-hour regional drain test.

13 regions, sticky routing, per-region CachedEmbeddingServer + rate
limiter; one region is drained for hours 21–26 of a 30-hour horizon (time-
scaled). The claim to reproduce: the GLOBAL cache hit rate stays stable
through the drain (re-homed users re-warm quickly at production access
rates).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core import server as srv_lib
from repro.core.config import CacheConfig, HOUR_MS, MINUTE_MS
from repro.core.hashing import Key64
from repro.core.ratelimit import RegionalRateLimiter
from repro.core.regions import DrainTestHarness, RegionRouter
from repro.data.access_patterns import (FIG6_KNOTS, InterArrivalDist,
                                        StreamConfig, generate_stream_fast)

N_REGIONS = 13
DIM = 16


def _tower(params, feats):
    return feats @ params


def run(report: Report | None = None, n_users: int = 4000,
        horizon_h: float = 30.0, batch: int = 16) -> dict:
    # batch=16 keeps a regional serve batch within ~minutes of sim time —
    # coarser batching aliases consecutive same-user accesses into one
    # lookup window and misrepresents the hit rate.
    report = report or Report()
    cfg = CacheConfig(model_id=1, model_type="ctr",
                      cache_ttl_ms=60 * MINUTE_MS,
                      failover_ttl_ms=2 * HOUR_MS,
                      n_buckets=1 << 12, ways=8, value_dim=DIM)
    servers, states = [], []
    for r in range(N_REGIONS):
        servers.append(srv_lib.CachedEmbeddingServer(
            cfg=cfg, tower_fn=_tower, miss_budget=batch))
        states.append(srv_lib.init_server_state(
            cfg, writebuf_capacity=batch * 2))

    router = RegionRouter(n_regions=N_REGIONS, locality=0.98, seed=1)
    limiter = RegionalRateLimiter.uniform(range(N_REGIONS),
                                          rate_per_s=500.0, burst_s=30.0)
    rng = np.random.default_rng(0)

    def feature_fn(ids, now_ms):
        return jnp.asarray(rng.standard_normal((ids.shape[0], DIM)),
                           jnp.float32)

    harness = DrainTestHarness(
        servers=servers, states=states, params=jnp.eye(DIM),
        router=router, limiter=limiter, feature_fn=feature_fn,
        key_fn=lambda ids: Key64.from_int(ids), batch=batch,
        flush_every_ms=30_000)

    stream_cfg = StreamConfig(n_users=n_users, horizon_s=horizon_h * 3600,
                              seed=4)
    times_ms, users = generate_stream_fast(stream_cfg,
                                           InterArrivalDist(FIG6_KNOTS))
    drain_lo, drain_hi = int(21 * 3.6e6), int(26 * 3.6e6)
    result = harness.run(users, times_ms, drain_region=3,
                         drain_window_ms=(drain_lo, drain_hi),
                         bucket_ms=int(1 * 3.6e6))

    hr = np.asarray(result["hit_rate"])
    buckets = np.asarray(result["bucket_ms"])
    warm = (buckets >= int(6 * 3.6e6))
    in_drain = warm & (buckets >= drain_lo) & (buckets < drain_hi)
    outside = warm & ~in_drain
    mean_out = float(hr[outside].mean())
    mean_in = float(hr[in_drain].mean()) if in_drain.any() else float("nan")
    dip_pp = (mean_out - mean_in) * 100
    load = np.asarray(result["region_load"])
    drained_load = load[in_drain][:, 3].sum() if in_drain.any() else -1
    report.add("fig10_hit_rate_outside_drain", 0.0, f"{mean_out:.3f}")
    report.add("fig10_hit_rate_during_drain", 0.0,
               f"{mean_in:.3f} dip={dip_pp:.2f}pp (paper: stable)")
    report.add("fig10_drained_region_load", 0.0,
               f"{int(drained_load)} requests during drain (should be 0)")
    return {"mean_out": mean_out, "mean_in": mean_in, "dip_pp": dip_pp,
            "drained_load": int(drained_load)}


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
