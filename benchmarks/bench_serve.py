"""Serve-path throughput: fused insert plan + single-dispatch serve_step.

Times the pieces ISSUE 1 rebuilt:

* ``insert`` with the fused single-sort plan, and ``flush_dual`` (one
  shared plan for direct+failover) vs two independent flushes;
* end-to-end ``serve_step`` on the jnp reference backend vs the pallas
  kernel backend (on CPU the pallas numbers run the interpreter — the
  jnp/pallas ratio is only meaningful on a real TPU backend, but the
  trajectory is tracked from this PR onward either way).

Returns a metrics dict merged into ``BENCH_serve.json`` by run.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as C
from repro.core import server as S
from repro.core import writebuf as wb_lib
from repro.core.config import CacheConfig
from repro.core.hashing import Key64

MIN = 60_000
DIM = 64


def _tower(params, feats):
    return feats @ params


def run(report):
    quick = getattr(common, "QUICK", False)
    B = 256 if quick else 1024
    n_buckets = 1 << 10 if quick else 1 << 12
    rng = np.random.default_rng(0)

    # ---------------------------------------------- fused insert / flush
    state = C.init_cache(n_buckets, 8, DIM)
    ids = rng.integers(0, 4 * B, size=B).astype(np.int64)
    keys = Key64.from_int(ids)
    vals = jnp.asarray(rng.standard_normal((B, DIM)), jnp.float32)
    insert_jit = jax.jit(lambda s, k, v: C.insert(s, k, v, 1000, MIN))
    us_insert = common.time_us(insert_jit, state, keys, vals)
    report.add(f"insert_fused_plan_B{B}", us_insert,
               f"{B / (us_insert * 1e-6):.0f}_writes_per_s")

    buf = wb_lib.init_writebuf(B, DIM)
    buf = wb_lib.append(buf, keys, vals, 1000, mask=jnp.ones((B,), bool))
    failover = C.init_cache(n_buckets, 8, DIM)

    def two_flushes(bf, d, f):
        d2, bf2, _ = wb_lib.flush(bf, d, 2000, MIN)
        f2, _, _ = wb_lib.flush(bf, f, 2000, 60 * MIN)
        return d2, f2, bf2

    flush_dual_jit = jax.jit(lambda bf, d, f: wb_lib.flush_dual(
        bf, d, f, 2000, MIN, 60 * MIN))
    two_flushes_jit = jax.jit(two_flushes)
    us_dual = common.time_us(flush_dual_jit, buf, state, failover)
    us_two = common.time_us(two_flushes_jit, buf, state, failover)
    report.add(f"flush_dual_B{B}", us_dual,
               f"vs_two_flushes={us_two / us_dual:.2f}x")

    # --------------------------------------------------- serve_step e2e
    serve_us = {}
    for backend in ("jnp", "pallas"):
        cfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=n_buckets,
                          ways=8, value_dim=DIM, cache_ttl_ms=5 * MIN,
                          failover_ttl_ms=60 * MIN, backend=backend)
        srv = S.CachedEmbeddingServer(cfg=cfg, tower_fn=_tower,
                                      miss_budget=B // 4)
        st = S.init_server_state(cfg, writebuf_capacity=B)
        params = jnp.asarray(rng.standard_normal((DIM, DIM)), jnp.float32)
        feats = jnp.asarray(rng.standard_normal((B, DIM)), jnp.float32)
        # warm the caches so the probe sees a realistic hit mix
        res = srv.serve_step(params, st, keys, feats, 0)
        st = srv.flush(res.state, 0)
        # plain jit (no donation) so the timing loop can reuse its args
        step = jax.jit(srv.serve_step)
        serve_us[backend] = common.time_us(step, params, st, keys, feats,
                                           1000)
        report.add(f"serve_step_{backend}_B{B}", serve_us[backend],
                   f"{B / (serve_us[backend] * 1e-6):.0f}_req_per_s")

    return {
        "batch": B,
        "insert_us": us_insert,
        "insert_writes_per_s": B / (us_insert * 1e-6),
        "flush_dual_us": us_dual,
        "flush_two_passes_us": us_two,
        "flush_dual_speedup": us_two / us_dual,
        "serve_step_us": serve_us,
        "serve_step_req_per_s": {k: B / (v * 1e-6)
                                 for k, v in serve_us.items()},
        "serve_ref_vs_pallas_speedup": serve_us["jnp"] / serve_us["pallas"],
    }
