"""Probe-kernel shootout: tiled vs per-query vs jnp reference.

The serve path's acceptance gate (ISSUE 1): at B=4096 on the default
backend the tiled Pallas probe must be ≥ 2× faster than the original
one-query-per-grid-step kernel, with bit-exact parity against
``core.cache.lookup``. Also times the dual probe (direct + failover in one
launch) against two tiled launches — the dispatch saving ``serve_step``
banks every batch.

Returns a metrics dict merged into ``BENCH_serve.json`` by run.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cache as C
from repro.core.hashing import Key64, bucket_index
from repro.kernels import cache_probe as pk

N_BUCKETS = 1 << 12
WAYS = 8
DIM = 64
TTL_MS = 60_000


def _populated_state(rng, n_keys):
    state = C.init_cache(N_BUCKETS, WAYS, DIM)
    ids = np.arange(n_keys, dtype=np.int64) * 7919
    keys = Key64.from_int(ids)
    vals = jnp.asarray(rng.standard_normal((n_keys, DIM)), jnp.float32)
    return C.insert(state, keys, vals, now_ms=0, ttl_ms=TTL_MS), ids


def run(report):
    quick = getattr(common, "QUICK", False)
    B = 512 if quick else 4096
    rng = np.random.default_rng(0)
    state, ids = _populated_state(rng, n_keys=B)
    failover, _ = _populated_state(rng, n_keys=B // 2)

    # ~60% hits, rest misses/expired-adjacent — a serving-like mix
    probe_ids = np.where(rng.uniform(size=B) < 0.6,
                         rng.choice(ids, size=B),
                         rng.integers(10 ** 9, 2 * 10 ** 9, size=B))
    k = Key64.from_int(probe_ids)
    buckets = bucket_index(k, N_BUCKETS)
    buckets_f = bucket_index(k, failover.n_buckets)
    args = (state.key_hi, state.key_lo, state.write_ts, state.values,
            k.hi, k.lo, buckets, 1000, TTL_MS)

    # parity gate first: tiled == core.cache.lookup, bit for bit
    want = C.lookup(state, k, 1000, TTL_MS)
    hit, vals, age, way = pk.cache_probe_tiled(*args)
    np.testing.assert_array_equal(hit, want.hit)
    np.testing.assert_array_equal(vals, want.values)
    np.testing.assert_array_equal(age, want.age_ms)
    np.testing.assert_array_equal(way, want.way)

    lookup_jit = jax.jit(lambda s, kk: C.lookup(s, kk, 1000, TTL_MS))
    us_ref = common.time_us(lookup_jit, state, k)
    us_tiled = common.time_us(pk.cache_probe_tiled, *args)
    # the per-query kernel pays B grid steps — seconds per call at B=4096
    # in interpret mode, so keep its sample count small
    us_perq = common.time_us(pk.cache_probe_perquery, *args,
                             warmup=1, iters=3 if not quick else 2)
    us_dual = common.time_us(
        pk.cache_probe_dual, state.key_hi, state.key_lo, state.write_ts,
        state.values, failover.key_hi, failover.key_lo, failover.write_ts,
        failover.values, k.hi, k.lo, buckets, buckets_f, 1000, TTL_MS,
        10 * TTL_MS)

    speedup = us_perq / us_tiled
    qps = lambda us: B / (us * 1e-6)
    report.add(f"probe_jnp_ref_B{B}", us_ref, f"{qps(us_ref):.0f}_qps")
    report.add(f"probe_tiled_B{B}", us_tiled,
               f"{qps(us_tiled):.0f}_qps;parity=exact")
    report.add(f"probe_perquery_B{B}", us_perq,
               f"tiled_speedup={speedup:.1f}x")
    report.add(f"probe_dual_B{B}", us_dual,
               f"vs_2x_tiled={2 * us_tiled / us_dual:.2f}x")
    return {
        "batch": B,
        "n_buckets": N_BUCKETS, "ways": WAYS, "dim": DIM,
        "probe_us": {"jnp_ref": us_ref, "tiled": us_tiled,
                     "perquery": us_perq, "dual": us_dual},
        "probe_qps": {"jnp_ref": qps(us_ref), "tiled": qps(us_tiled),
                      "perquery": qps(us_perq), "dual": qps(us_dual)},
        "tiled_vs_perquery_speedup": speedup,
        "dual_vs_two_tiled_speedup": 2 * us_tiled / us_dual,
        "tiled_parity_with_lookup": "exact",
    }
