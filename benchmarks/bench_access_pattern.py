"""Fig. 2 reproduction: CDF of consecutive user-tower inference intervals.

Paper anchors: 52% ≤ 1 min, 76% ≤ 10 min, 88% ≤ 1 h. The generator's
empirical stream must land on them (±1.5 pp) by construction.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Report
from repro.data.access_patterns import (StreamConfig, consecutive_interval_cdf,
                                        generate_stream_fast)

PAPER = [(60.0, 0.52), (600.0, 0.76), (3600.0, 0.88)]


def run(report: Report | None = None, n_users: int = 4000,
        horizon_h: float = 48.0) -> dict:
    report = report or Report()
    cfg = StreamConfig(n_users=n_users, horizon_s=horizon_h * 3600, seed=7)
    times_ms, users = generate_stream_fast(cfg)
    probes = np.asarray([t for t, _ in PAPER])
    got = consecutive_interval_cdf(times_ms, users, probes)
    out = {}
    for (t, want), g in zip(PAPER, got):
        label = f"fig2_cdf_{int(t)}s"
        err_pp = abs(g - want) * 100
        report.add(label, 0.0,
                   f"cdf={g:.3f} paper={want:.2f} err={err_pp:.2f}pp")
        out[label] = (float(g), want)
    report.add("fig2_events", 0.0, f"n={len(users)} users={n_users}")
    return out


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
