"""Table 3 reproduction: failover cache cuts the model-fallback rate.

Each row: a (task × stage) model whose inference fails at the paper's
w/o-cache rate; the failover cache (1–2 h TTL) recovers failures for users
seen within the TTL. Runs the REAL CachedEmbeddingServer (core/server.py)
over the calibrated request stream with injected failures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core import server as srv_lib
from repro.core.config import CacheConfig, HOUR_MS, MINUTE_MS
from repro.core.hashing import Key64
from repro.data.access_patterns import (FIG6_KNOTS, InterArrivalDist,
                                        StreamConfig, generate_stream_fast)
from repro.ft.failure import FailureInjector

# (name, failover TTL h, w/o-cache fallback %, paper w/ cache %)
TABLE3 = [
    ("cvr_retrieval", 1, 0.7, 0.3),
    ("ctr_retrieval", 1, 0.6, 0.1),
    ("cvr_first_a", 1, 5.9, 0.1),
    ("cvr_first_b", 1, 6.5, 0.1),
    ("ctr_first_a", 1, 1.5, 0.5),
    ("ctr_first_b", 1, 1.4, 0.1),
    ("ctr_second", 2, 0.05, 0.01),
    ("cvr_second", 2, 0.1, 0.04),
]

DIM = 16


def _tower(params, feats):
    return feats @ params


def run(report: Report | None = None, n_users: int = 1500,
        horizon_h: float = 24.0, batch: int = 512) -> dict:
    report = report or Report()
    out = {}
    params = jnp.eye(DIM, dtype=jnp.float32)
    stream_cfg = StreamConfig(n_users=n_users, horizon_s=horizon_h * 3600,
                              seed=5)
    times_ms, users = generate_stream_fast(stream_cfg,
                                           InterArrivalDist(FIG6_KNOTS))

    for name, fo_h, rate_wo, paper_w in TABLE3:
        cfg = CacheConfig(model_id=1, model_type=name,
                          cache_ttl_ms=5 * MINUTE_MS,
                          failover_ttl_ms=fo_h * HOUR_MS,
                          n_buckets=1 << 12, ways=8, value_dim=DIM)
        # direct cache DISABLED for this arm: isolate failover behaviour by
        # setting direct TTL to 0 (every request attempts inference)
        cfg = CacheConfig(**{**cfg.__dict__, "cache_ttl_ms": 0})
        server = srv_lib.CachedEmbeddingServer(cfg=cfg, tower_fn=_tower,
                                               miss_budget=batch)
        state = srv_lib.init_server_state(cfg, writebuf_capacity=batch * 2)
        injector = FailureInjector(base_rate=rate_wo / 100.0,
                                   seed=hash(name) % 2**31)
        fallbacks = requests = failures = 0
        rng = np.random.default_rng(1)
        for lo in range(0, min(len(users), 200_000) - batch + 1, batch):
            ids = users[lo:lo + batch]
            now = int(times_ms[lo + batch - 1])
            feats = jnp.asarray(
                rng.standard_normal((batch, DIM)), jnp.float32)
            fail = jnp.asarray(injector.mask(batch, now))
            res = server.jit_serve_step(params, state,
                                        Key64.from_int(ids), feats, now,
                                        fail)
            state = server.jit_flush(res.state, now)
            s = jax.device_get(res.stats)  # erlint: allow[ER002] — one fetch per dispatch
            requests += int(s["requests"])
            failures += int(s["tower_failures"])
            fallbacks += int(s["fallbacks"])
        got_wo = 100.0 * failures / max(requests, 1)
        got_w = 100.0 * fallbacks / max(requests, 1)
        label = f"table3_{name}"
        report.add(label, 0.0,
                   f"wo_cache={got_wo:.2f}% w_cache={got_w:.3f}% "
                   f"paper={rate_wo}->{paper_w}% "
                   f"reduction={100*(1-got_w/max(got_wo,1e-9)):.0f}%")
        out[label] = {"wo": got_wo, "w": got_w,
                      "paper_wo": rate_wo, "paper_w": paper_w}
    mean_red = float(np.mean(
        [100 * (1 - v["w"] / max(v["wo"], 1e-9)) for v in out.values()]))
    report.add("table3_mean_reduction", 0.0,
               f"{mean_red:.1f}% (paper: 79.6% avg)")
    return out


if __name__ == "__main__":
    r = Report()
    run(r)
    r.print_csv(header=True)
