"""Streaming serve driver: scan-vs-loop dispatch overhead + coalescing.

Two questions ISSUE 5 asks of the hot loop (DESIGN.md §9):

* **Dispatch amortization** — the same Zipf stream served by the
  per-step Python loop (one ``jit_serve_step`` dispatch + one stats
  ``jax.device_get`` per step, the pre-scan driver) vs ``serve_many``
  (S steps per dispatch, counters fetched once per dispatch). Sustained
  req/s of both arms; the scan must win at S ≥ 64.
* **In-batch inference coalescing** — tower calls saved per Zipf skew:
  the same stream served with ``coalesce_misses`` off vs on, counting
  actual tower forward passes. With skew a = 1.2 the coalesced tower
  must run strictly less than once per request, and the coalesced
  embeddings must match the uncoalesced ones bit for bit.

Writes ``BENCH_stream.json`` (schema ``ercache-bench-stream/1``) — the
trajectory file for the streaming axis; ``scripts/render_experiments.py``
renders it into docs/benchmarks.md and CI asserts the two gates above.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import server as S
from repro.core.config import CacheConfig
from repro.core.hashing import Key64
from repro.core.metrics import ServingCounters

DIM = 32
MIN = 60_000
ZIPF_SKEWS = (1.1, 1.2, 1.5)
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_stream.json")


def _tower(params, feats):
    return feats @ params


def _make_server(batch, n_buckets, coalesce=False):
    cfg = CacheConfig(model_id=1, model_type="ctr", n_buckets=n_buckets,
                      ways=8, value_dim=DIM, cache_ttl_ms=60 * MIN,
                      failover_ttl_ms=120 * MIN, coalesce_misses=coalesce)
    srv = S.CachedEmbeddingServer(cfg=cfg, tower_fn=_tower,
                                  miss_budget=batch)
    return srv


def _zipf_stream(rng, a, n_users, n_steps, batch):
    """(n_steps, batch) Zipf-skewed user ids — duplicate-heavy at high a."""
    ids = (rng.zipf(a, size=(n_steps, batch)) - 1) % n_users
    return ids.astype(np.int64)


def _stage(ids):
    n_steps, batch = ids.shape
    flat = Key64.from_int(ids.reshape(-1))
    keys = Key64(hi=flat.hi.reshape(n_steps, batch),
                 lo=flat.lo.reshape(n_steps, batch))
    # features as a function of the user: coalescing's broadcast premise
    feats = jnp.asarray(
        (ids[..., None] * np.arange(1, DIM + 1)) % 97, jnp.float32)
    now = jnp.arange(n_steps, dtype=jnp.int32) * 100
    return keys, feats, now


def _run_loop(srv, keys, feats, now, batch, flush_every):
    """The pre-scan driver: one dispatch + one stats fetch PER STEP."""
    state = S.init_server_state(srv.cfg, writebuf_capacity=batch * 8)
    params = jnp.eye(DIM, dtype=jnp.float32)
    n_steps = keys.hi.shape[0]
    counters = ServingCounters()
    t0 = time.perf_counter()
    for i in range(n_steps):
        k = Key64(hi=keys.hi[i], lo=keys.lo[i])
        res = srv.jit_serve_step(params, state, k, feats[i], now[i])
        state = res.state
        # batched transfer: ONE device_get for the step's stats dict
        # (not per-key int() conversions) — still a sync every step
        counters.merge(ServingCounters.from_stats(
            jax.device_get(res.stats)))  # erlint: allow[ER002] — see above
        if (i + 1) % flush_every == 0:
            state = srv.jit_flush(state, now[i])
    state = srv.jit_flush(state, now[-1])
    # erlint: allow[ER002] — final drain so the timer covers real work
    jax.block_until_ready(jax.tree_util.tree_leaves(state))
    return time.perf_counter() - t0, counters


def _run_scan(srv, keys, feats, now, batch, flush_every, chunk_steps):
    """The scan driver: chunk_steps steps per dispatch, ONE fetch each."""
    state = S.init_server_state(srv.cfg, writebuf_capacity=batch * 8)
    params = jnp.eye(DIM, dtype=jnp.float32)
    n_steps = keys.hi.shape[0]
    counters = ServingCounters()
    t0 = time.perf_counter()
    for lo in range(0, n_steps, chunk_steps):
        hi = min(lo + chunk_steps, n_steps)
        sl = slice(lo, hi)
        k = Key64(hi=keys.hi[sl], lo=keys.lo[sl])
        state, acc, _ = srv.jit_serve_many(
            params, state, k, feats[sl], now[sl],
            flush_every=flush_every, collect=False)
        # erlint: allow[ER002] — the one sanctioned fetch per dispatch
        counters.merge(ServingCounters.from_stats(jax.device_get(acc)))
    # erlint: allow[ER002] — final drain so the timer covers real work
    jax.block_until_ready(jax.tree_util.tree_leaves(state))
    return time.perf_counter() - t0, counters


def run(report):
    quick = getattr(common, "QUICK", False)
    batch = 128 if quick else 256
    chunk_steps = 64
    n_steps = 128 if quick else 256
    n_users = batch * 8
    flush_every = 4
    n_buckets = 1 << 12
    rng = np.random.default_rng(0)

    # ---------------------------------------------- scan vs loop (a=1.2)
    ids = _zipf_stream(rng, 1.2, n_users, n_steps, batch)
    keys, feats, now = _stage(ids)
    srv = _make_server(batch, n_buckets)
    # warm both jits on a throwaway state (first chunk shape + tail shape)
    _run_loop(srv, keys, feats, now, batch, flush_every)
    _run_scan(srv, keys, feats, now, batch, flush_every, chunk_steps)
    loop_s, c_loop = _run_loop(srv, keys, feats, now, batch, flush_every)
    scan_s, c_scan = _run_scan(srv, keys, feats, now, batch, flush_every,
                               chunk_steps)
    assert c_scan.requests == c_loop.requests == n_steps * batch
    # identical stream + flush schedule ⇒ identical serving outcome
    assert c_scan.direct_hits == c_loop.direct_hits
    reqs = n_steps * batch
    loop_rps = reqs / loop_s
    scan_rps = reqs / scan_s
    speedup = scan_rps / loop_rps
    report.add(f"stream_loop_B{batch}", loop_s / n_steps * 1e6,
               f"{loop_rps:.0f}_req_per_s")
    report.add(f"stream_scan_S{chunk_steps}_B{batch}",
               scan_s / n_steps * 1e6,
               f"{scan_rps:.0f}_req_per_s;speedup={speedup:.2f}x")

    # ------------------------------- coalescing: tower calls vs Zipf skew
    srv_on = _make_server(batch, n_buckets, coalesce=True)
    per_skew = {}
    for a in ZIPF_SKEWS:
        ids_a = _zipf_stream(np.random.default_rng(1), a, n_users,
                             n_steps, batch)
        keys_a, feats_a, now_a = _stage(ids_a)
        _, c_off = _run_scan(srv, keys_a, feats_a, now_a, batch,
                             flush_every, chunk_steps)
        _, c_on = _run_scan(srv_on, keys_a, feats_a, now_a, batch,
                            flush_every, chunk_steps)
        assert c_on.requests == c_off.requests
        assert c_on.direct_hits == c_off.direct_hits
        saved = c_off.tower_inferences - c_on.tower_inferences
        per_skew[f"{a:g}"] = {
            "tower_inferences_uncoalesced": c_off.tower_inferences,
            "tower_inferences_coalesced": c_on.tower_inferences,
            "tower_calls_saved": saved,
            "infer_per_request_uncoalesced":
                c_off.tower_inferences / c_off.requests,
            "infer_per_request_coalesced":
                c_on.tower_inferences / c_on.requests,
        }
        report.add(f"stream_coalesce_zipf{a:g}", 0.0,
                   f"saved={saved}_tower_calls"
                   f";per_req={c_on.tower_inferences / c_on.requests:.3f}")

    # --------------------------- coalesced-vs-uncoalesced output parity
    par_ids = _zipf_stream(np.random.default_rng(2), 1.2, n_users, 8,
                           batch)
    par_keys, par_feats, par_now = _stage(par_ids)
    params = jnp.eye(DIM, dtype=jnp.float32)
    _, _, ys_off = srv.serve_many(
        params, S.init_server_state(srv.cfg, writebuf_capacity=batch * 8),
        par_keys, par_feats, par_now, flush_every=flush_every)
    _, _, ys_on = srv_on.serve_many(
        params,
        S.init_server_state(srv_on.cfg, writebuf_capacity=batch * 8),
        par_keys, par_feats, par_now, flush_every=flush_every)
    try:
        for x, y in zip(jax.tree_util.tree_leaves(ys_off),
                        jax.tree_util.tree_leaves(ys_on)):
            np.testing.assert_array_equal(x, y)
        parity = "exact"
    except AssertionError:
        parity = "MISMATCH"          # recorded; the CI gate fails on it

    metrics = {
        "schema": "ercache-bench-stream/1",
        "quick": quick,
        "backend": jax.default_backend(),
        "batch": batch,
        "chunk_steps": chunk_steps,
        "n_steps": n_steps,
        "users": n_users,
        "flush_every": flush_every,
        "zipf_a": 1.2,
        "loop_req_per_s": loop_rps,
        "scan_req_per_s": scan_rps,
        "scan_vs_loop_speedup": speedup,
        "per_skew": per_skew,
        "coalesce_parity": parity,
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return metrics
