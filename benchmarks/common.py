"""Shared helpers for the paper-artifact benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

# Set by run.py --quick: benches shrink shapes/iterations for CI smoke runs.
QUICK = False
# Set by run.py from --json: '' disables ALL metrics-file writes, including
# benches that own their file (bench_multi_model's BENCH_multi_model.json).
WRITE_JSON = True


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time of fn(*args) in microseconds (jit-warmed)."""
    for _ in range(warmup):
        r = fn(*args)
    _block(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _block(r):
    import jax
    for leaf in jax.tree_util.tree_leaves(r):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


class Report:
    """Collects ``name,us_per_call,derived`` rows for run.py's CSV."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float = 0.0, derived: str = ""):
        self.rows.append((name, us_per_call, derived))

    def print_csv(self, header: bool = False):
        if header:
            print("name,us_per_call,derived")
        for name, us, derived in self.rows:
            print(f"{name},{us:.2f},{derived}")
