"""Warm-restart payoff: checkpointed restore vs cold start after a kill.

The durability layer (ft/snapshot + ft/checkpoint + ft/elastic) only earns
its fsyncs if a restored cache measurably out-serves a cold one on the
same post-crash stream. This bench runs the full kill/restore
fault-injection harness (launch/serve.py ``run_serving_restart``): a
Zipf replay snapshotted at checkpoint boundaries, a FailureInjector burst
that picks the kill step, a deliberately torn final checkpoint the restore
must skip, then four recoveries over the SAME stream — bit-exact same
geometry, elastic 2×-grow, elastic ½×-shrink, and cold.

Acceptance (asserted by the CI docs job on the written JSON):

* ``warm_vs_cold_gain`` > 0 — the warm restore's recovery hit rate beats
  the cold start's (ISSUE 6: the checkpoint pays for itself);
* ``parity.pass`` — grown tables preserve EVERY live snapshot entry,
  shrunk tables serve a value-bit-exact subset;
* ``torn_step_skipped`` — restore landed on the committed snapshot, not
  the torn one;
* ``ledger_continuous`` — restored ServingCounters resume additively
  across the kill.

Writes ``BENCH_restart.json`` (schema ``ercache-bench-restart/1``) — the
single source of truth for this axis (not duplicated into
BENCH_serve.json, same rationale as bench_eviction/bench_overload).
"""
from __future__ import annotations

import json
import os
import shutil

from benchmarks import common
from repro.launch.serve import run_serving_restart

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_restart.json")


def run(report):
    quick = getattr(common, "QUICK", False)
    kw = dict(arch="sasrec", backend="jnp", seed=0, log=lambda *a: None)
    if quick:
        kw.update(pre_steps=120, recovery_steps=60, users=1200, batch=128,
                  checkpoint_every=30, n_buckets=1 << 11, chunk_steps=30)
    else:
        kw.update(pre_steps=240, recovery_steps=120, users=3000, batch=256,
                  checkpoint_every=40, n_buckets=1 << 12, chunk_steps=40)

    out = run_serving_restart(**kw)
    workdir = out.get("workdir")
    if workdir and os.path.isdir(workdir):        # tmpdir of snapshots
        shutil.rmtree(workdir, ignore_errors=True)
        out["workdir"] = None

    for name, v in out["variants"].items():
        report.add(f"restart_{name}", 0.0,
                   f"mode={v['mode']}_hit={v['recovery_hit_rate']:.4f}"
                   f"_infer={v['recovery_tower_inferences']}")
    report.add("restart_warm_vs_cold", 0.0,
               f"gain={out['warm_vs_cold_gain']:+.4f}"
               f"_parity={out['parity']['pass']}"
               f"_torn_skipped={out['torn_step_skipped']}")

    metrics = {
        "schema": "ercache-bench-restart/1",
        "quick": quick,
        **{k: out[k] for k in (
            "users", "batch", "n_buckets", "zipf_a", "ttl_min", "step_ms",
            "kill_step", "checkpoint_every", "recovery_steps", "backend",
            "pre_hit_rate", "torn_step_skipped", "ledger_continuous",
            "warm_vs_cold_gain", "variants", "parity", "wall_s")},
    }
    if getattr(common, "WRITE_JSON", True):
        with open(JSON_PATH, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"# wrote {JSON_PATH}")
    return None
